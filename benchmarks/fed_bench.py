"""Accuracy-vs-communication across the four federation strategies
(DESIGN.md §9) — the paper's comm-overhead argument as a tracked artifact.

One planted mixture is partitioned over clients with Dirichlet
heterogeneity, then every strategy the runtime serves — one-shot
``FedGenGMM``, iterative ``DEM``, ``FedEM`` (partial participation +
local epochs, Tian et al.) and ``FedKMeans`` (per-center label stats,
Garst et al.) — trains through ``repro.api`` on the SAME split. Each row
reports model quality next to the realized communication ledger, so the
headline claim (one round of parameter blocks vs hundreds of rounds of
sufficient statistics at comparable fit) is a number, not prose.

In full mode (standalone ``python benchmarks/fed_bench.py``) the results
are written to ``BENCH_comm.json`` (repo root) in machine-readable form:

    {"backend", "setting": {n, d, k, clients, alpha, scheme},
     "strategies": {name: {metric, value, rounds, uplink_floats,
                           downlink_floats, payload_mb, seconds}}}

``payload_mb`` comes from the dtype-aware ledger
(``CommStats.total_mb``), so an f64 run doubles the wire volume at
identical float counts. GMM strategies report ``avg_loglik`` on the
training union (Eq. 2); FedKMeans has no likelihood and reports
``inertia_per_row`` (lower is better) — the ``metric`` field names the
unit so downstream tooling never compares across meanings.

Full and dry modes also stage the **population benchmark** (DESIGN.md §9,
"cohort execution"): a 1k-client Dirichlet population on which every
FedEM round samples a cohort of m clients, timed across
m ∈ {8, 32, 128, 1000} against a frozen copy of the PR-6
train-all+zero-mask path. The ``population`` section of the report
carries the wall-clock-vs-cohort-size curve and the m=32 speedup; full
mode FAILS (RuntimeError) if sampling a 32-cohort is not at least 5x
faster per round than masking all 1000 — the tentpole claim, guarded.

Full and dry modes also stage the **privacy benchmark** (DESIGN.md §11,
"uplink transforms"): utility vs epsilon under an EQUAL total (eps,
delta) budget for the one-shot release (FedGen spends the whole budget
on its single round, ``GaussianDP(rounds=1)``) against the iterative
strategies (DEM / FedEM deplete the same budget across their round
budget, ``GaussianDP(rounds=R)`` — the Huang et al. depletion problem
the paper cites). The ``privacy`` section carries one utility-vs-epsilon
curve per strategy with the ledger's realized ``epsilon_spent`` per
point, plus the no-DP baseline utilities; full mode FAILS (RuntimeError)
if FedGen's one-shot utility at eps=1 regresses below the committed
floor.

Full and dry modes also stage the **async benchmark** (DESIGN.md §12,
"async federation"): wall-clock-to-target-loglik of the synchronous
regime (``run_async`` at ``buffer_size = cohort_size, lookahead = 0`` —
bit-identical to ``run_rounds``) against buffered staleness-weighted
rounds (small buffer, deep lookahead, polynomial damping) on a
1k-client Dirichlet population with heterogeneous per-client sizes.
Both arms start from one shared model, run for real (the quality
trajectory is the actual per-combine model, scored on the training
union), and are placed on a **simulated federation clock**: one host
time-shares all 1000 clients, so host wall-clock measures the
simulator, not the federation — instead each client's update takes
``local_rows / CLIENT_ROWS_PER_SEC`` of federation time (latency
proportional to its data — the heterogeneous-sizes straggler model),
clients run concurrently, the server is instantaneous, and a combine
completes when ``buffer_size`` updates have ARRIVED. The same clock
covers both arms: at ``buffer = cohort, lookahead = 0`` it degenerates
to the synchronous barrier (each round gated by the slowest cohort
member), which is exactly the tax the async runtime removes. The
``async`` section records each arm's trajectory summary and the
speedup to the common target (start + 90% of the sync arm's
improvement). Full mode FAILS (RuntimeError) if the buffered arm is not
at least 2x faster to target — the tentpole claim, guarded.

Quick (CI) mode scales down and prints rows only; ``--dry-run`` shrinks
to tiny N / capped rounds and *validates the report schema* instead of
recording timings — that is what the CI bench-smoke lane runs.
"""
from __future__ import annotations

import argparse
import dataclasses
import heapq
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (DEM, DPConfig, FedEM, FedGenGMM, FedKMeans,
                       FitConfig, score)
from repro.fed import GaussianDP
from repro.core.dem import DEMStrategy
from repro.core.em import SufficientStats, e_step_stats, m_step
from repro.core.partition import partition
from repro.fed import CyclicSampler, run_async, run_rounds
from repro.fed.strategies import FedEMStrategy

N_FULL, N_QUICK, N_DRY = 20_000, 4_000, 512
D, K, CLIENTS, ALPHA = 8, 5, 8, 0.5
JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_comm.json"

STRATEGIES = ("fedgen", "dem", "fedem", "fedkmeans")
ROW_FIELDS = ("metric", "value", "rounds", "uplink_floats",
              "downlink_floats", "payload_mb", "seconds")

# population benchmark: C clients, cohort sizes to sweep, rounds timed
POP_FULL = dict(clients=1_000, n=50_000, cohorts=(8, 32, 128, 1_000),
                guard_m=32, rounds=6)
POP_DRY = dict(clients=48, n=960, cohorts=(4, 16, 48), guard_m=16,
               rounds=2)
POP_MIN_SPEEDUP = 5.0

# privacy benchmark: utility vs epsilon under equal TOTAL (eps, delta)
# budgets — one-shot FedGen (rounds=1) vs iterative DEM/FedEM depleting
# the budget across their round budget
PRIV_FULL = dict(n=8_000, epsilons=(0.25, 1.0, 4.0), rounds=30,
                 delta=1e-5)
PRIV_DRY = dict(n=512, epsilons=(1.0,), rounds=3, delta=1e-5)
PRIV_STRATEGIES = ("fedgen", "dem", "fedem")
# committed floor for FedGen's one-shot utility at eps=1 on the full
# setting (avg loglik on the training union; measured 3.03 on the CPU
# backend — regenerate deliberately when the mechanism changes)
FEDGEN_EPS1_FLOOR = 2.5

# async benchmark: sync regime (buffer = cohort, zero lookahead) vs
# buffered staleness-weighted rounds on one 1k-client population.
# cohort % buffer == 0 keeps every buffered combine a single-group
# reduce (updates from one dispatch batch), so the async arm's combines
# are genuinely ~buffer/cohort of the sync arm's per-combine client work.
# The deep lookahead (in-flight window = buffer + lookahead = 256) is
# the async design point: concurrency decoupled from combine size,
# where the barrier pins sync concurrency to its cohort. Sync
# time-to-target is approximately cohort-invariant (a bigger cohort
# buys proportionally fewer rounds but each round's barrier waits on a
# worse straggler), so the 64-cohort baseline is not a strawman.
ASYNC_FULL = dict(clients=1_000, n=50_000, cohort=64, sync_rounds=40,
                  buffer=16, lookahead=240, alpha=0.5, async_rounds=400)
ASYNC_DRY = dict(clients=24, n=720, cohort=8, sync_rounds=3, buffer=4,
                 lookahead=8, alpha=0.5, async_rounds=8)
ASYNC_MIN_SPEEDUP = 2.0
ASYNC_TARGET_FRACTION = 0.9
# federation-clock latency model: a client's local step takes
# local_rows / CLIENT_ROWS_PER_SEC seconds of federation time. The
# absolute rate only fixes the unit — every reported speedup is a
# ratio of clocks built from the same rate.
CLIENT_ROWS_PER_SEC = 2_000.0


def validate_report(report: dict) -> None:
    """Schema gate for the tracked JSON; raises ValueError listing every
    violation rather than stopping at the first."""
    problems = []
    for field in ("backend", "setting", "strategies"):
        if field not in report:
            problems.append(f"missing top-level field {field!r}")
    setting = report.get("setting", {})
    for field in ("n", "d", "k", "clients"):
        if not isinstance(setting.get(field), int):
            problems.append(f"setting.{field} must be an int")
    if not isinstance(setting.get("alpha"), (int, float)):
        problems.append("setting.alpha must be a number")
    strategies = report.get("strategies", {})
    missing = set(STRATEGIES) - set(strategies)
    if missing:
        problems.append(f"missing strategies: {sorted(missing)}")
    for name, row in strategies.items():
        if row.get("metric") not in ("avg_loglik", "inertia_per_row"):
            problems.append(f"strategies.{name}.metric must name the "
                            f"quality unit, got {row.get('metric')!r}")
        for field in ("value",):
            if not isinstance(row.get(field), (int, float)):
                problems.append(f"strategies.{name}.{field} must be a "
                                f"number, got {row.get(field)!r}")
        for field in ("rounds", "uplink_floats", "downlink_floats"):
            v = row.get(field)
            if not isinstance(v, int) or v < 0:
                problems.append(f"strategies.{name}.{field} must be a "
                                f"non-negative int, got {v!r}")
        for field in ("payload_mb", "seconds"):
            v = row.get(field)
            if not isinstance(v, (int, float)) or v < 0:
                problems.append(f"strategies.{name}.{field} must be a "
                                f"non-negative number, got {v!r}")
    if "population" in report:
        _validate_population(report["population"], problems)
    if "privacy" in report:
        _validate_privacy(report["privacy"], problems)
    if "async" in report:
        _validate_async(report["async"], problems)
    if problems:
        raise ValueError("BENCH_comm.json schema violations:\n  "
                         + "\n  ".join(problems))


def _validate_population(section: dict, problems: list[str]) -> None:
    for field in ("clients", "n", "rounds"):
        v = section.get(field)
        if not isinstance(v, int) or v < 1:
            problems.append(f"population.{field} must be a positive int, "
                            f"got {v!r}")
    curve = section.get("curve")
    if not isinstance(curve, list) or not curve:
        problems.append("population.curve must be a non-empty list")
        curve = []
    for i, pt in enumerate(curve):
        m = pt.get("cohort_size")
        if not isinstance(m, int) or m < 1:
            problems.append(f"population.curve[{i}].cohort_size must be "
                            f"a positive int, got {m!r}")
        s = pt.get("seconds_per_round")
        if not isinstance(s, (int, float)) or s < 0:
            problems.append(f"population.curve[{i}].seconds_per_round "
                            f"must be a non-negative number, got {s!r}")
    base = section.get("baseline_zero_mask", {})
    if not isinstance(base.get("seconds_per_round"), (int, float)):
        problems.append("population.baseline_zero_mask.seconds_per_round "
                        "must be a number")
    if not isinstance(section.get("guard_cohort_size"), int):
        problems.append("population.guard_cohort_size must be an int")
    if not isinstance(section.get("guard_speedup"), (int, float)):
        problems.append("population.guard_speedup must be a number")


def _validate_privacy(section: dict, problems: list[str]) -> None:
    for field in ("n", "rounds_budget"):
        v = section.get(field)
        if not isinstance(v, int) or v < 1:
            problems.append(f"privacy.{field} must be a positive int, "
                            f"got {v!r}")
    delta = section.get("delta")
    if not isinstance(delta, float) or not 0.0 < delta < 1.0:
        problems.append(f"privacy.delta must be a float in (0, 1), "
                        f"got {delta!r}")
    epsilons = section.get("epsilons")
    if (not isinstance(epsilons, list) or not epsilons
            or not all(isinstance(e, (int, float)) and e > 0
                       for e in epsilons)):
        problems.append("privacy.epsilons must be a non-empty list of "
                        "positive numbers")
        epsilons = []
    baseline = section.get("baseline", {})
    curves = section.get("curves", {})
    for name in PRIV_STRATEGIES:
        if not isinstance(baseline.get(name), (int, float)):
            problems.append(f"privacy.baseline.{name} must be a number "
                            "(no-DP utility)")
        curve = curves.get(name)
        if not isinstance(curve, list) or len(curve) != len(epsilons):
            problems.append(f"privacy.curves.{name} must have one point "
                            f"per epsilon ({len(epsilons)})")
            continue
        for i, pt in enumerate(curve):
            e = pt.get("epsilon")
            if not isinstance(e, (int, float)) or e <= 0:
                problems.append(f"privacy.curves.{name}[{i}].epsilon must "
                                f"be a positive number, got {e!r}")
            if not isinstance(pt.get("value"), (int, float)):
                problems.append(f"privacy.curves.{name}[{i}].value must "
                                "be a number")
            spent = pt.get("epsilon_spent")
            if not isinstance(spent, (int, float)) or spent < 0:
                problems.append(f"privacy.curves.{name}[{i}]"
                                f".epsilon_spent must be a non-negative "
                                f"number, got {spent!r}")
            elif isinstance(e, (int, float)) and spent > e * (1 + 1e-6):
                problems.append(f"privacy.curves.{name}[{i}] overspends "
                                f"the accountant: epsilon_spent {spent!r} "
                                f"> budget {e!r}")
            r = pt.get("rounds")
            if not isinstance(r, int) or r < 1:
                problems.append(f"privacy.curves.{name}[{i}].rounds must "
                                f"be a positive int, got {r!r}")
    for field in ("guard_floor", "guard_value"):
        if not isinstance(section.get(field), (int, float)):
            problems.append(f"privacy.{field} must be a number")


def _validate_async(section: dict, problems: list[str]) -> None:
    for field in ("clients", "n", "cohort_size", "buffer_size"):
        v = section.get(field)
        if not isinstance(v, int) or v < 1:
            problems.append(f"async.{field} must be a positive int, "
                            f"got {v!r}")
    la = section.get("lookahead")
    if not isinstance(la, int) or la < 0:
        problems.append(f"async.lookahead must be a non-negative int, "
                        f"got {la!r}")
    alpha = section.get("staleness_alpha")
    if not isinstance(alpha, (int, float)) or alpha < 0:
        problems.append(f"async.staleness_alpha must be a non-negative "
                        f"number, got {alpha!r}")
    for field in ("start_avg_loglik", "target_avg_loglik"):
        if not isinstance(section.get(field), (int, float)):
            problems.append(f"async.{field} must be a number, "
                            f"got {section.get(field)!r}")
    rate = section.get("client_rows_per_sec")
    if not isinstance(rate, (int, float)) or rate <= 0:
        problems.append(f"async.client_rows_per_sec must be a positive "
                        f"number, got {rate!r}")
    if not isinstance(section.get("clock_model"), str):
        problems.append(f"async.clock_model must name the federation "
                        f"clock, got {section.get('clock_model')!r}")
    for arm in ("sync", "async"):
        row = section.get(arm)
        if not isinstance(row, dict):
            problems.append(f"async.{arm} must be an arm dict")
            continue
        r = row.get("rounds")
        if not isinstance(r, int) or r < 1:
            problems.append(f"async.{arm}.rounds must be a positive int, "
                            f"got {r!r}")
        if not isinstance(row.get("final_avg_loglik"), (int, float)):
            problems.append(f"async.{arm}.final_avg_loglik must be a "
                            f"number, got {row.get('final_avg_loglik')!r}")
        for field in ("seconds", "seconds_to_target", "host_seconds"):
            v = row.get(field)
            # seconds_to_target is None when the arm never reached the
            # target inside its round budget (full mode guards async
            # reaching it; tiny dry-run arms legitimately may not)
            if field == "seconds_to_target" and v is None:
                continue
            if not isinstance(v, (int, float)) or v < 0:
                problems.append(f"async.{arm}.{field} must be a "
                                f"non-negative number, got {v!r}")
    sp = section.get("speedup_to_target")
    if sp is not None and not isinstance(sp, (int, float)):
        problems.append(f"async.speedup_to_target must be a number or "
                        f"null, got {sp!r}")


@dataclasses.dataclass(frozen=True)
class _ZeroMaskFedEM(FedEMStrategy):
    """Frozen copy of the PR-6 FedEM participation path (train-all +
    zero-mask): every one of the C clients runs its E-step every round
    and non-members multiply their stats by 0. This is the baseline the
    cohort execution layer is measured against — kept inside the bench
    so the comparison survives even after the production path forgets
    this shape ever existed."""

    def local_step(self, state, x, w, idx):
        active = None
        if self.participation < 1.0:
            c, m = self.n_clients, self.cohort_size()
            start = (state.rnd * m) % c
            active = ((idx - start) % c) < m
        gmm = state.gmm
        stats = e_step_stats(gmm, x, w, self.backend, self.chunk)
        for _ in range(self.local_epochs - 1):
            gmm = m_step(stats, state.reg_covar)
            stats = e_step_stats(gmm, x, w, self.backend, self.chunk)
        if active is not None:
            stats = jax.tree.map(
                lambda s: s * jnp.asarray(active, s.dtype), stats)
        return stats


def _pop_strategy(cls, m: int, clients: int) -> FedEMStrategy:
    # tol=1e-30 never triggers the ring-buffer convergence check, so the
    # loop always runs the full static max_rounds — clean per-round time
    return cls(k=K, covariance_type="diag", backend="auto", chunk=None,
               init="separated", host=False, tol=1e-30, reg_covar=1e-6,
               participation=m / clients, local_epochs=1,
               n_clients=clients)


def _timed_rounds(strategy, split, state0, rounds, sampler=None) -> float:
    """Seconds per round, after a warmup run pays for compilation."""
    def go():
        res = run_rounds(strategy, split, key=jax.random.key(0),
                         state0=state0, max_rounds=rounds, sampler=sampler)
        jax.block_until_ready(res.global_gmm.means)
        return res
    go()  # warmup: compile
    t0 = time.time()
    go()
    return (time.time() - t0) / rounds


def run_population(dry_run: bool = False) -> tuple[dict, list[str]]:
    """The O(cohort)-vs-O(population) measurement: per-round wall clock
    of cohort-sampled FedEM across cohort sizes on one Dirichlet
    population, against the frozen zero-mask baseline at the guard
    cohort size."""
    p = POP_DRY if dry_run else POP_FULL
    c, n, rounds = p["clients"], p["n"], p["rounds"]
    rng = np.random.default_rng(2)
    mus = rng.normal(0, 5, (K, D)).astype(np.float32)
    y = rng.integers(0, K, n)
    x = (mus[y] + rng.normal(0, 0.6, (n, D))).astype(np.float32)
    split = partition(np.random.default_rng(3), x, y, c, "dirichlet",
                      ALPHA)

    # one shared initial model so every timed run does identical math
    # (the round-0 state itself is per-strategy: the convergence ring
    # buffer's length depends on the cohort-cycle period)
    from repro.fed.runtime import make_backend
    ref = _pop_strategy(FedEMStrategy, p["guard_m"], c)
    gmm0 = ref.init_state(jax.random.key(1), make_backend(split)).gmm

    section = {"clients": c, "n": n, "rounds": rounds, "alpha": ALPHA,
               "scheme": "dirichlet", "curve": []}
    rows = []
    for m in p["cohorts"]:
        strat = _pop_strategy(FedEMStrategy, m, c)
        sampler = CyclicSampler(c, m) if m < c else None
        state0 = strat.state_from_gmm(gmm0, dtype=jnp.float32)
        secs = _timed_rounds(strat, split, state0, rounds, sampler)
        section["curve"].append(
            {"cohort_size": m, "seconds_per_round": round(secs, 6)})
        rows.append(f"fed_pop/cohort_m{m}/C{c}n{n},{secs * 1e6:.0f},"
                    f"{rounds}r")

    base = _pop_strategy(_ZeroMaskFedEM, p["guard_m"], c)
    base_secs = _timed_rounds(
        base, split, base.state_from_gmm(gmm0, dtype=jnp.float32), rounds)
    section["baseline_zero_mask"] = {
        "cohort_size": p["guard_m"],
        "seconds_per_round": round(base_secs, 6)}
    guard_secs = next(pt["seconds_per_round"] for pt in section["curve"]
                      if pt["cohort_size"] == p["guard_m"])
    speedup = base_secs / max(guard_secs, 1e-12)
    section["guard_cohort_size"] = p["guard_m"]
    section["guard_speedup"] = round(speedup, 3)
    rows.append(f"fed_pop/zero_mask_baseline_m{p['guard_m']}/C{c}n{n},"
                f"{base_secs * 1e6:.0f},{speedup:.1f}x")
    if not dry_run and speedup < POP_MIN_SPEEDUP:
        raise RuntimeError(
            f"cohort execution regressed: m={p['guard_m']} cohort round "
            f"is only {speedup:.2f}x faster than the zero-mask "
            f"train-all baseline (guard: >= {POP_MIN_SPEEDUP}x)")
    return section, rows


def run_privacy(dry_run: bool = False) -> tuple[dict, list[str]]:
    """Utility vs epsilon under an equal TOTAL (eps, delta) budget:
    one-shot FedGen (``GaussianDP(rounds=1)`` via the ``dp=`` sugar)
    against iterative DEM / FedEM whose per-round release gets
    ``eps / rounds_budget`` so the accountant depletes the same total.
    DP sensitivities assume features in the unit cube, so this section
    plants its mixture inside [0, 1]^d."""
    p = PRIV_DRY if dry_run else PRIV_FULL
    n, rounds, delta = p["n"], p["rounds"], p["delta"]
    rng = np.random.default_rng(7)
    mus = rng.uniform(0.2, 0.8, (K, D)).astype(np.float32)
    y = rng.integers(0, K, n)
    x = np.clip(mus[y] + rng.normal(0, 0.05, (n, D)), 0.0, 1.0)
    x = x.astype(np.float32)
    split = partition(np.random.default_rng(8), x, y, CLIENTS,
                      "dirichlet", ALPHA)
    xj = jnp.asarray(x)
    cfg = FitConfig(max_iter=rounds)
    key = jax.random.key(11)

    def loglik(gmm):
        return float(score(gmm, xj, config=cfg))

    def runners(dp_cfg, t_iter):
        return {
            "fedgen": lambda: FedGenGMM(k_clients=K, k_global=K, h=40,
                                        config=cfg, dp=dp_cfg).run(
                split, key=jax.random.fold_in(key, 0)),
            "dem": lambda: DEM(K, config=cfg, transform=t_iter).run(
                split, key=jax.random.fold_in(key, 1)),
            "fedem": lambda: FedEM(K, participation=0.5, local_epochs=1,
                                   config=cfg, transform=t_iter).run(
                split, key=jax.random.fold_in(key, 2)),
        }

    section = {"n": n, "delta": float(delta), "rounds_budget": rounds,
               "alpha": ALPHA, "scheme": "dirichlet",
               "epsilons": [float(e) for e in p["epsilons"]],
               "baseline": {},
               "curves": {name: [] for name in PRIV_STRATEGIES}}
    rows = []
    for name, runner in runners(None, None).items():
        section["baseline"][name] = round(loglik(runner().global_gmm), 5)
    for eps in section["epsilons"]:
        dp_cfg = DPConfig(epsilon=eps, delta=float(delta))
        t_iter = GaussianDP(epsilon=eps, delta=float(delta), rounds=rounds)
        for name, runner in runners(dp_cfg, t_iter).items():
            res = runner()
            pt = {"epsilon": eps,
                  "epsilon_spent": round(float(res.comm.epsilon_spent), 6),
                  "rounds": int(res.comm.rounds),
                  "value": round(loglik(res.global_gmm), 5)}
            section["curves"][name].append(pt)
            rows.append(f"fed_priv/{name}/eps{eps:g}/N{n},"
                        f"{pt['rounds']}r spent={pt['epsilon_spent']:.3f} "
                        f"avg_loglik={pt['value']:.4f} "
                        f"(no-DP {section['baseline'][name]:.4f})")
    fedgen_curve = section["curves"]["fedgen"]
    guard_pt = next((pt for pt in fedgen_curve if pt["epsilon"] == 1.0),
                    fedgen_curve[-1])
    section["guard_floor"] = FEDGEN_EPS1_FLOOR
    section["guard_value"] = guard_pt["value"]
    if not dry_run and guard_pt["value"] < FEDGEN_EPS1_FLOOR:
        raise RuntimeError(
            f"one-shot DP release regressed: FedGen utility at "
            f"eps={guard_pt['epsilon']:g} is {guard_pt['value']:.4f}, "
            f"below the committed floor {FEDGEN_EPS1_FLOOR}")
    return section, rows


def _federation_clock(sizes, cohort, buffer, lookahead,
                      n_combines) -> list[float]:
    """Per-combine completion times on the simulated federation clock.

    Replays :func:`run_async`'s dispatch windowing (top up whole sampler
    cohorts — the cyclic windows — whenever fewer than
    ``buffer + lookahead`` updates are in flight) under the latency
    model: client ``i``'s update arrives ``sizes[i] /
    CLIENT_ROWS_PER_SEC`` seconds after dispatch, all in-flight clients
    compute concurrently (they are distinct devices — concurrency across
    clients is free in a federation; the server is the serialization
    point and combines instantaneously), and a combine completes when
    ``buffer`` updates have ARRIVED. At ``buffer = cohort, lookahead =
    0`` this degenerates to the synchronous barrier: dispatch the
    cohort, wait for its slowest member, combine — so one clock covers
    both arms. One deliberate approximation: the driver itself consumes
    updates in dispatch order (the deterministic surrogate that keeps
    runs reproducible and the sync configuration bit-identical to
    ``run_rounds``), while the clock counts arrivals the way a
    production buffered server would see them; membership of the g-th
    combine differs between the two views but the clients, the total
    work, and the steady-state staleness distribution are the same."""
    c = len(sizes)
    heap: list[float] = []   # arrival times of in-flight updates
    clock, b, out = 0.0, 0, []
    for _ in range(n_combines):
        while len(heap) < buffer + lookahead:
            start = (b * cohort) % c
            for i in (start + np.arange(cohort)) % c:
                heapq.heappush(heap, clock + sizes[i] /
                               CLIENT_ROWS_PER_SEC)
            b += 1
        for _ in range(buffer):
            clock = max(clock, heapq.heappop(heap))
        out.append(clock)
    return out


def run_async_bench(dry_run: bool = False) -> tuple[dict, list[str]]:
    """Federation-clock-to-target-loglik: the synchronous regime vs
    buffered staleness-weighted rounds on one Dirichlet population
    (heterogeneous per-client sizes), both arms from one shared initial
    model. Quality is real — every combine's model comes from an actual
    ``run_async`` execution and is scored on the training union — and
    the time axis is the simulated federation clock of
    :func:`_federation_clock` (host wall-clock is recorded per arm as
    ``host_seconds`` but measures the one-machine simulator, which
    time-shares all C clients, not the federation being modeled)."""
    p = ASYNC_DRY if dry_run else ASYNC_FULL
    c, n, cohort = p["clients"], p["n"], p["cohort"]
    rng = np.random.default_rng(13)
    mus = rng.normal(0, 5, (K, D)).astype(np.float32)
    y = rng.integers(0, K, n)
    x = (mus[y] + rng.normal(0, 0.6, (n, D))).astype(np.float32)
    split = partition(np.random.default_rng(14), x, y, c, "dirichlet",
                      ALPHA)
    sizes = np.asarray(split.sizes, dtype=float)
    xj = jnp.asarray(x)
    cfg = FitConfig()
    key = jax.random.key(17)

    # tol=0 never converges early: both arms run their full round budget
    # and the trajectory alone decides time-to-target
    strat = DEMStrategy(k=K, init="separated", tol=0.0)
    from repro.fed.runtime import make_backend
    state0 = strat.init_state(key, make_backend(split))
    sampler = CyclicSampler(c, cohort)

    def arm(buffer, lookahead, rounds):
        snaps = []
        t0 = time.time()
        run_async(strat, split, key=key, state0=state0,
                  max_rounds=rounds, sampler=sampler, buffer_size=buffer,
                  lookahead=lookahead, staleness=p["alpha"],
                  progress=lambda v, s, st: snaps.append(s.gmm))
        host = time.time() - t0
        clock = _federation_clock(sizes, cohort, buffer, lookahead,
                                  len(snaps))
        lls = [float(score(g, xj, config=cfg)) for g in snaps]
        return list(zip(clock, lls)), host

    arms = {"sync": (cohort, 0, p["sync_rounds"]),
            "async": (p["buffer"], p["lookahead"], p["async_rounds"])}
    traj, hosts = {}, {}
    for name, (buffer, lookahead, rounds) in arms.items():
        traj[name], hosts[name] = arm(buffer, lookahead, rounds)

    start_ll = float(score(state0.gmm, xj, config=cfg))
    sync_final = traj["sync"][-1][1]
    target = start_ll + ASYNC_TARGET_FRACTION * (sync_final - start_ll)

    def to_target(points):
        return next((round(t, 6) for t, ll in points if ll >= target),
                    None)

    section = {"clients": c, "n": n, "alpha": ALPHA, "scheme": "dirichlet",
               "cohort_size": cohort, "buffer_size": p["buffer"],
               "lookahead": p["lookahead"],
               "staleness_alpha": float(p["alpha"]),
               "client_rows_per_sec": CLIENT_ROWS_PER_SEC,
               "clock_model": "arrivals: latency = rows/rate, "
                              "concurrent clients, instant server",
               "start_avg_loglik": round(start_ll, 5),
               "target_avg_loglik": round(target, 5)}
    rows = []
    for name in arms:
        points = traj[name]
        section[name] = {"rounds": len(points),
                         "final_avg_loglik": round(points[-1][1], 5),
                         "seconds": round(points[-1][0], 6),
                         "seconds_to_target": to_target(points),
                         "host_seconds": round(hosts[name], 3)}
        rows.append(f"fed_async/{name}/C{c}n{n}m{cohort},"
                    f"{points[-1][0] * 1e6:.0f},{len(points)}r "
                    f"to_target={section[name]['seconds_to_target']}s "
                    f"final={section[name]['final_avg_loglik']:.4f}")
    t_sync = section["sync"]["seconds_to_target"]
    t_async = section["async"]["seconds_to_target"]
    speedup = (round(t_sync / t_async, 3)
               if t_sync is not None and t_async else None)
    section["speedup_to_target"] = speedup
    rows.append(f"fed_async/speedup_to_target/C{c}n{n},{speedup},"
                f"target={target:.4f}")
    if not dry_run:
        if t_async is None:
            raise RuntimeError(
                f"async federation regressed: the buffered arm never "
                f"reached the target loglik {target:.4f} inside "
                f"{p['async_rounds']} combines")
        if speedup is None or speedup < ASYNC_MIN_SPEEDUP:
            raise RuntimeError(
                f"async federation regressed: buffered rounds are only "
                f"{speedup}x faster to target than the sync regime "
                f"(guard: >= {ASYNC_MIN_SPEEDUP}x)")
    return section, rows


def _ledger_row(metric: str, value: float, comm, seconds: float) -> dict:
    return {
        "metric": metric,
        "value": round(float(value), 5),
        "rounds": int(comm.rounds),
        "uplink_floats": int(comm.uplink_floats),
        "downlink_floats": int(comm.downlink_floats),
        "payload_mb": round(comm.total_mb, 6),
        "seconds": round(seconds, 3),
    }


def run(quick: bool = True, dry_run: bool = False) -> list[str]:
    n = N_DRY if dry_run else (N_QUICK if quick else N_FULL)
    max_iter = 5 if dry_run else 100
    rng = np.random.default_rng(0)
    mus = rng.normal(0, 5, (K, D)).astype(np.float32)
    y = rng.integers(0, K, n)
    x = (mus[y] + rng.normal(0, 0.6, (n, D))).astype(np.float32)
    split = partition(np.random.default_rng(1), x, y, CLIENTS,
                      "dirichlet", ALPHA)
    xj = jnp.asarray(x)
    cfg = FitConfig(max_iter=max_iter)
    key = jax.random.key(0)

    def loglik(gmm):
        return float(score(gmm, xj, config=cfg))

    runners = {
        "fedgen": lambda: FedGenGMM(k_clients=K, k_global=K, h=40,
                                    config=cfg).run(
            split, key=jax.random.fold_in(key, 0)),
        "dem": lambda: DEM(K, config=cfg).run(
            split, key=jax.random.fold_in(key, 1)),
        "fedem": lambda: FedEM(K, participation=0.5, local_epochs=2,
                               config=cfg).run(
            split, key=jax.random.fold_in(key, 2)),
        "fedkmeans": lambda: FedKMeans(K, config=cfg).run(
            split, key=jax.random.fold_in(key, 3)),
    }

    report = {
        "backend": jax.default_backend(),
        "setting": {"n": n, "d": D, "k": K, "clients": CLIENTS,
                    "alpha": ALPHA, "scheme": "dirichlet"},
        "strategies": {},
    }
    rows = []
    for name, runner in runners.items():
        t0 = time.time()
        res = runner()
        secs = time.time() - t0
        if name == "fedkmeans":
            row = _ledger_row("inertia_per_row", float(res.inertia) / n,
                              res.comm, secs)
        else:
            row = _ledger_row("avg_loglik", loglik(res.global_gmm),
                              res.comm, secs)
        report["strategies"][name] = row
        rows.append(f"fed_comm/{name}/N{n}d{D}K{K}c{CLIENTS}a{ALPHA},"
                    f"{secs * 1e6:.0f},{row['rounds']}r "
                    f"{row['payload_mb']:.4f}MB {row['metric']}="
                    f"{row['value']:.4f}")
    # population benchmark: full mode measures + guards the 1k-client
    # speedup claim; dry mode runs a tiny population to validate the
    # schema; quick (orchestrator) mode skips it for CI latency
    if dry_run or not quick:
        section, pop_rows = run_population(dry_run=dry_run)
        report["population"] = section
        rows.extend(pop_rows)
        priv, priv_rows = run_privacy(dry_run=dry_run)
        report["privacy"] = priv
        rows.extend(priv_rows)
        async_section, async_rows = run_async_bench(dry_run=dry_run)
        report["async"] = async_section
        rows.extend(async_rows)
    validate_report(report)
    if dry_run:
        rows.append("# dry-run: report schema OK, numbers are placeholders")
        return rows
    if not quick:
        JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--dry-run", action="store_true",
                        help="tiny-N schema-validation mode (CI bench-smoke "
                             "lane): runs all four strategies, validates "
                             "the report schema, writes nothing")
    cli = parser.parse_args()
    for r in run(quick=cli.dry_run, dry_run=cli.dry_run):
        print(r)
    if not cli.dry_run:
        print(f"# wrote {JSON_PATH}")
