"""Accuracy-vs-communication across the four federation strategies
(DESIGN.md §9) — the paper's comm-overhead argument as a tracked artifact.

One planted mixture is partitioned over clients with Dirichlet
heterogeneity, then every strategy the runtime serves — one-shot
``FedGenGMM``, iterative ``DEM``, ``FedEM`` (partial participation +
local epochs, Tian et al.) and ``FedKMeans`` (per-center label stats,
Garst et al.) — trains through ``repro.api`` on the SAME split. Each row
reports model quality next to the realized communication ledger, so the
headline claim (one round of parameter blocks vs hundreds of rounds of
sufficient statistics at comparable fit) is a number, not prose.

In full mode (standalone ``python benchmarks/fed_bench.py``) the results
are written to ``BENCH_comm.json`` (repo root) in machine-readable form:

    {"backend", "setting": {n, d, k, clients, alpha, scheme},
     "strategies": {name: {metric, value, rounds, uplink_floats,
                           downlink_floats, payload_mb, seconds}}}

``payload_mb`` comes from the dtype-aware ledger
(``CommStats.total_mb``), so an f64 run doubles the wire volume at
identical float counts. GMM strategies report ``avg_loglik`` on the
training union (Eq. 2); FedKMeans has no likelihood and reports
``inertia_per_row`` (lower is better) — the ``metric`` field names the
unit so downstream tooling never compares across meanings.

Quick (CI) mode scales down and prints rows only; ``--dry-run`` shrinks
to tiny N / capped rounds and *validates the report schema* instead of
recording timings — that is what the CI bench-smoke lane runs.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (DEM, FedEM, FedGenGMM, FedKMeans, FitConfig, score)
from repro.core.partition import partition

N_FULL, N_QUICK, N_DRY = 20_000, 4_000, 512
D, K, CLIENTS, ALPHA = 8, 5, 8, 0.5
JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_comm.json"

STRATEGIES = ("fedgen", "dem", "fedem", "fedkmeans")
ROW_FIELDS = ("metric", "value", "rounds", "uplink_floats",
              "downlink_floats", "payload_mb", "seconds")


def validate_report(report: dict) -> None:
    """Schema gate for the tracked JSON; raises ValueError listing every
    violation rather than stopping at the first."""
    problems = []
    for field in ("backend", "setting", "strategies"):
        if field not in report:
            problems.append(f"missing top-level field {field!r}")
    setting = report.get("setting", {})
    for field in ("n", "d", "k", "clients"):
        if not isinstance(setting.get(field), int):
            problems.append(f"setting.{field} must be an int")
    if not isinstance(setting.get("alpha"), (int, float)):
        problems.append("setting.alpha must be a number")
    strategies = report.get("strategies", {})
    missing = set(STRATEGIES) - set(strategies)
    if missing:
        problems.append(f"missing strategies: {sorted(missing)}")
    for name, row in strategies.items():
        if row.get("metric") not in ("avg_loglik", "inertia_per_row"):
            problems.append(f"strategies.{name}.metric must name the "
                            f"quality unit, got {row.get('metric')!r}")
        for field in ("value",):
            if not isinstance(row.get(field), (int, float)):
                problems.append(f"strategies.{name}.{field} must be a "
                                f"number, got {row.get(field)!r}")
        for field in ("rounds", "uplink_floats", "downlink_floats"):
            v = row.get(field)
            if not isinstance(v, int) or v < 0:
                problems.append(f"strategies.{name}.{field} must be a "
                                f"non-negative int, got {v!r}")
        for field in ("payload_mb", "seconds"):
            v = row.get(field)
            if not isinstance(v, (int, float)) or v < 0:
                problems.append(f"strategies.{name}.{field} must be a "
                                f"non-negative number, got {v!r}")
    if problems:
        raise ValueError("BENCH_comm.json schema violations:\n  "
                         + "\n  ".join(problems))


def _ledger_row(metric: str, value: float, comm, seconds: float) -> dict:
    return {
        "metric": metric,
        "value": round(float(value), 5),
        "rounds": int(comm.rounds),
        "uplink_floats": int(comm.uplink_floats),
        "downlink_floats": int(comm.downlink_floats),
        "payload_mb": round(comm.total_mb, 6),
        "seconds": round(seconds, 3),
    }


def run(quick: bool = True, dry_run: bool = False) -> list[str]:
    n = N_DRY if dry_run else (N_QUICK if quick else N_FULL)
    max_iter = 5 if dry_run else 100
    rng = np.random.default_rng(0)
    mus = rng.normal(0, 5, (K, D)).astype(np.float32)
    y = rng.integers(0, K, n)
    x = (mus[y] + rng.normal(0, 0.6, (n, D))).astype(np.float32)
    split = partition(np.random.default_rng(1), x, y, CLIENTS,
                      "dirichlet", ALPHA)
    xj = jnp.asarray(x)
    cfg = FitConfig(max_iter=max_iter)
    key = jax.random.key(0)

    def loglik(gmm):
        return float(score(gmm, xj, config=cfg))

    runners = {
        "fedgen": lambda: FedGenGMM(k_clients=K, k_global=K, h=40,
                                    config=cfg).run(
            split, key=jax.random.fold_in(key, 0)),
        "dem": lambda: DEM(K, config=cfg).run(
            split, key=jax.random.fold_in(key, 1)),
        "fedem": lambda: FedEM(K, participation=0.5, local_epochs=2,
                               config=cfg).run(
            split, key=jax.random.fold_in(key, 2)),
        "fedkmeans": lambda: FedKMeans(K, config=cfg).run(
            split, key=jax.random.fold_in(key, 3)),
    }

    report = {
        "backend": jax.default_backend(),
        "setting": {"n": n, "d": D, "k": K, "clients": CLIENTS,
                    "alpha": ALPHA, "scheme": "dirichlet"},
        "strategies": {},
    }
    rows = []
    for name, runner in runners.items():
        t0 = time.time()
        res = runner()
        secs = time.time() - t0
        if name == "fedkmeans":
            row = _ledger_row("inertia_per_row", float(res.inertia) / n,
                              res.comm, secs)
        else:
            row = _ledger_row("avg_loglik", loglik(res.global_gmm),
                              res.comm, secs)
        report["strategies"][name] = row
        rows.append(f"fed_comm/{name}/N{n}d{D}K{K}c{CLIENTS}a{ALPHA},"
                    f"{secs * 1e6:.0f},{row['rounds']}r "
                    f"{row['payload_mb']:.4f}MB {row['metric']}="
                    f"{row['value']:.4f}")
    validate_report(report)
    if dry_run:
        rows.append("# dry-run: report schema OK, numbers are placeholders")
        return rows
    if not quick:
        JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--dry-run", action="store_true",
                        help="tiny-N schema-validation mode (CI bench-smoke "
                             "lane): runs all four strategies, validates "
                             "the report schema, writes nothing")
    cli = parser.parse_args()
    for r in run(quick=cli.dry_run, dry_run=cli.dry_run):
        print(r)
    if not cli.dry_run:
        print(f"# wrote {JSON_PATH}")
