"""Figure 3: anomaly-detection AUC-PR vs heterogeneity level, per dataset
and method (point-wise log-likelihood scores, §5.8)."""
from __future__ import annotations

from benchmarks.common import csv_rows, load_quick, run_methods

DATASETS_Q = ["vehicle", "smd"]
DATASETS_FULL = ["mnist", "covertype", "rwhar", "wadi", "vehicle", "smd"]
ALPHAS = {"dirichlet": [0.1, 0.5, 5.0], "quantity": [1, 2, 3]}


def run(quick: bool = True, seeds=(0,)) -> list[str]:
    rows = []
    for name in (DATASETS_Q if quick else DATASETS_FULL):
        ds = load_quick(name, quick=quick)
        alphas = ALPHAS[ds.scheme]
        if quick:
            alphas = alphas[:2]
        for alpha in alphas:
            for seed in seeds:
                res = run_methods(ds, alpha, seed)
                rows += csv_rows("fig3_anomaly", name, alpha, res, "auc_pr")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
