"""Figure 4: anomaly-detection AUC-PR vs number of clients at fixed
heterogeneity (paper: 20..320 clients; CPU-scale: 10..80)."""
from __future__ import annotations

from benchmarks.common import load_quick, run_methods

DATASETS_Q = ["smd"]
DATASETS_FULL = ["covertype", "rwhar", "wadi", "smd"]


def run(quick: bool = True, seeds=(0,)) -> list[str]:
    rows = []
    clients = [10, 20, 40] if quick else [10, 20, 40, 80]
    for name in (DATASETS_Q if quick else DATASETS_FULL):
        ds = load_quick(name, quick=quick)
        alpha = 0.2 if ds.scheme == "dirichlet" else 1
        for n in clients:
            for seed in seeds:
                res = run_methods(ds, alpha, seed, n_clients=n,
                                  methods=("fedgen", "dem3", "central"))
                for m, r in res.items():
                    rows.append(
                        f"fig4_clients/{name}/n={n}/{m},"
                        f"{r['seconds'] * 1e6:.0f},{r['auc_pr']:.4f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
