"""Print a one-line-per-combination summary of dry-run JSON records."""
import json
import sys
from pathlib import Path


def main(d="experiments/dryrun"):
    rows = []
    for f in sorted(Path(d).glob("*.json")):
        r = json.loads(f.read_text())
        rows.append(r)
    print(f"{'arch':>20} {'shape':>12} {'mesh':>9} {'flops':>10} "
          f"{'bytes':>10} {'coll_B':>10} {'peakGiB':>8} {'cmp_s':>6}")
    for r in rows:
        print(f"{r['arch']:>20} {r['shape']:>12} {r['mesh']:>9} "
              f"{r['cost']['flops']:>10.2e} "
              f"{r['cost']['bytes_accessed']:>10.2e} "
              f"{r['collective_bytes_total']:>10.2e} "
              f"{r['memory']['peak_bytes'] / 2**30:>8.2f} "
              f"{r['compile_s']:>6.1f}")


if __name__ == "__main__":
    main(*sys.argv[1:])
