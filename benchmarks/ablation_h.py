"""Beyond-paper ablation: sensitivity to H (Eq. 5 — synthetic samples per
incoming component). The paper fixes H=100 without a sensitivity study;
this sweep shows the fitness/cost trade-off (server-side EM cost is linear
in |S| = H * sum K_c)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import load_quick
from repro.api import FedGenGMM, GMMEstimator
from repro.core import partition


def run(quick: bool = True, seeds=(0,)) -> list[str]:
    rows = []
    hs = [5, 25, 100] if quick else [5, 10, 25, 50, 100, 200]
    ds = load_quick("vehicle", quick=quick)
    for seed in seeds:
        rng = np.random.default_rng(seed)
        split = partition(rng, ds.x_train, ds.y_train, ds.n_clients,
                          ds.scheme, 1)
        xj = jnp.asarray(ds.x_train)
        bench = GMMEstimator(ds.k_global, seed=99).fit(xj)
        rows.append(f"ablation_h/vehicle/central,0,"
                    f"{float(bench.gmm_.score(xj)):.4f}")
        for h in hs:
            t0 = time.time()
            fr = FedGenGMM(k_clients=ds.k_global, k_global=ds.k_global,
                           h=h, seed=seed).run(split)
            ll = float(fr.global_gmm.score(xj))
            rows.append(f"ablation_h/vehicle/H={h},"
                        f"{(time.time() - t0) * 1e6:.0f},{ll:.4f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
