"""Out-of-core training end to end (DESIGN.md §7) through the public
estimator API: the same `GMMEstimator` / `FedGenGMM` facades dispatch on
the input type, so handing them a DataSource (or a list of per-client
sources) is all it takes to train on data that is never resident — a
memory-mapped ``.npy`` file, ragged client shards via ConcatSource, and
the full one-shot FedGenGMM pipeline where the server refit replays the
merged mixture as a seeded synthetic block stream.

Run: PYTHONPATH=src python examples/out_of_core.py
"""
import tempfile
from pathlib import Path

import jax
import numpy as np

from repro.api import FedGenGMM, FitConfig, GMMEstimator, score
from repro.data import (ArraySource, ConcatSource, NpyFileSource,
                        SyntheticGMMSource)

CFG = FitConfig(chunk_size=8192)  # one config, every stage streams

rng = np.random.default_rng(0)
mus = np.array([[-5, 0, 0, 0], [5, 0, 0, 0], [0, 7, 0, 0]], np.float32)
comp = rng.integers(0, 3, 60_000)
x = (mus[comp] + rng.normal(0, 0.7, (60_000, 4))).astype(np.float32)

with tempfile.TemporaryDirectory() as tmp:
    # 1. mmap'd file: only one (chunk_size, d) block is in memory at a time.
    path = Path(tmp) / "rows.npy"
    np.save(path, x)
    src = NpyFileSource(path)
    res = GMMEstimator(3, config=CFG).fit(src).result_
    print(f"mmap fit:      avg loglik {float(res.log_likelihood):+.3f} "
          f"in {int(res.n_iter)} EM iters over {src.num_rows} rows")

    # 2. ragged shards, no padding or masks: ConcatSource re-chunks across
    #    boundaries, so this fit is bit-identical to fitting the union.
    shards = [ArraySource(x[:11_000]), ArraySource(x[11_000:37_500]),
              ArraySource(x[37_500:])]
    res_cat = GMMEstimator(3, config=CFG).fit(ConcatSource(shards)).result_
    same = np.array_equal(np.asarray(res_cat.gmm.means),
                          np.asarray(res.gmm.means))
    print(f"concat fit:    bit-identical to mmap fit: {same}")

    # 3. one-shot federated pipeline, everything streamed: run() sees a
    #    list of sources, so local fits stream per client and the server
    #    refit replays a synthetic source (synthetic="auto" -> "source").
    fr = FedGenGMM(k_clients=3, k_global=3, h=200, seed=1,
                   config=CFG).run(shards)
    ll = float(score(fr.global_gmm, src, config=CFG))
    print(f"fedgen (src):  global avg loglik {ll:+.3f}; replay set "
          f"|S|={fr.synthetic.num_rows} rows, never materialized "
          f"({type(fr.synthetic).__name__})")

    # 4. the replay trick standalone: a 10M-row virtual dataset from the
    #    fitted model — regenerated block-by-block from one seeded key.
    replay = SyntheticGMMSource(fr.global_gmm, 10_000_000, jax.random.key(2))
    ll10m = float(score(fr.global_gmm, replay,
                        config=FitConfig(chunk_size=65536)))
    print(f"replay score:  avg loglik {ll10m:+.3f} over {replay.num_rows:,} "
          f"virtual rows, O(chunk) memory")
