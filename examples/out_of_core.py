"""Out-of-core training end to end (DESIGN.md §7).

Fits GMMs on data that is never resident: a memory-mapped ``.npy`` file,
ragged client shards via ConcatSource, and the full one-shot FedGenGMM
pipeline where every client streams its own source and the server refit
replays the merged mixture as a seeded synthetic block stream.

Run: PYTHONPATH=src python examples/out_of_core.py
"""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedgengmm_from_sources, fit_gmm, score_streaming
from repro.data import (ArraySource, ConcatSource, NpyFileSource,
                        SyntheticGMMSource)

CHUNK = 8192

rng = np.random.default_rng(0)
mus = np.array([[-5, 0, 0, 0], [5, 0, 0, 0], [0, 7, 0, 0]], np.float32)
comp = rng.integers(0, 3, 60_000)
x = (mus[comp] + rng.normal(0, 0.7, (60_000, 4))).astype(np.float32)

with tempfile.TemporaryDirectory() as tmp:
    # 1. mmap'd file: only one (CHUNK, d) block is in memory at a time.
    path = Path(tmp) / "rows.npy"
    np.save(path, x)
    src = NpyFileSource(path)
    res = fit_gmm(jax.random.key(0), src, k=3, chunk_size=CHUNK)
    print(f"mmap fit:      avg loglik {float(res.log_likelihood):+.3f} "
          f"in {int(res.n_iter)} EM iters over {src.num_rows} rows")

    # 2. ragged shards, no padding or masks: ConcatSource re-chunks across
    #    boundaries, so this fit is bit-identical to fitting the union.
    shards = [ArraySource(x[:11_000]), ArraySource(x[11_000:37_500]),
              ArraySource(x[37_500:])]
    res_cat = fit_gmm(jax.random.key(0), ConcatSource(shards), k=3,
                      chunk_size=CHUNK)
    same = np.array_equal(np.asarray(res_cat.gmm.means),
                          np.asarray(res.gmm.means))
    print(f"concat fit:    bit-identical to mmap fit: {same}")

    # 3. one-shot federated pipeline, everything streamed: local fits from
    #    per-client sources, server refit from a synthetic replay source.
    fr = fedgengmm_from_sources(jax.random.key(1), shards, k_clients=3,
                                k_global=3, h=200, chunk_size=CHUNK)
    ll = float(score_streaming(fr.global_gmm, src, chunk_size=CHUNK))
    print(f"fedgen (src):  global avg loglik {ll:+.3f}; replay set "
          f"|S|={fr.synthetic.num_rows} rows, never materialized "
          f"({type(fr.synthetic).__name__})")

    # 4. the replay trick standalone: a 10M-row virtual dataset from the
    #    fitted model — regenerated block-by-block from one seeded key.
    replay = SyntheticGMMSource(fr.global_gmm, 10_000_000, jax.random.key(2))
    ll10m = float(score_streaming(fr.global_gmm, replay, chunk_size=65536))
    print(f"replay score:  avg loglik {ll10m:+.3f} over {replay.num_rows:,} "
          f"virtual rows, O(chunk) memory")
