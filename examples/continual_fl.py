"""Continual one-shot federated learning (the paper's stated future work,
implemented): windows of drifting client data, one communication round per
window, server-side memory controls the stability/plasticity trade-off.

    PYTHONPATH=src python examples/continual_fl.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition
from repro.core.continual import continual_round, init_state

rng = np.random.default_rng(0)
mus = rng.normal(0, 6, (4, 4)).astype(np.float32)


def window(active, n=900, seed=0):
    r = np.random.default_rng(seed)
    y = r.choice(active, size=n)
    x = (mus[y] + r.normal(0, 0.5, (n, 4))).astype(np.float32)
    return x, y.astype(np.int64)


def eval_on(gmm, active, seed=99):
    x, _ = window(active, 1500, seed)
    return float(gmm.score(jnp.asarray(x)))


schedule = [[0, 1], [0, 1], [2, 3], [2, 3]]  # drift at window 3
for memory in (0.0, 0.6):
    state = init_state()
    print(f"\n== memory={memory} ==")
    for t, active in enumerate(schedule):
        x, y = window(active, seed=t)
        split = partition(np.random.default_rng(t), x, y, 4, "dirichlet", 1.0)
        state = continual_round(jax.random.key(t), state,
                                jnp.asarray(split.data),
                                jnp.asarray(split.mask), split.sizes,
                                k_clients=2, k_global=4, h=60,
                                memory=memory)
        print(f"window {t} (modes {active}): "
              f"ll_old={eval_on(state.global_gmm, [0, 1]):7.2f}  "
              f"ll_new={eval_on(state.global_gmm, [2, 3]):7.2f}  "
              f"rounds_total={state.rounds_total}")
print("\nmemory=0 forgets the old modes after drift; memory=0.6 retains "
      "them — still one round per window.")
