"""Train federated, publish per round, serve with hot model swap — the
paper's anomaly-detection story (§5.4) end to end on the §10 serving
engine.

A trainer thread runs distributed EM (``DEM``) over out-of-core clients
and PUBLISHES the global model after every communication round
(a delegating strategy wrapper + ``repro.serve.ModelStore``). The main
thread serves a stream of scoring requests through
``repro.api.Scorer``: each newly published round hot-swaps in between
batches — no request is dropped, and every batch of scores carries the
version (= round) of the model that produced it. The last batches,
scored by the converged model, separate in-distribution traffic from
out-of-distribution traffic.

    PYTHONPATH=src python examples/serve_anomaly.py
"""
import tempfile
import threading
import time

import jax
import numpy as np

from repro.api import Scorer, fit_federated
from repro.core.dem import DEMStrategy
from repro.data.sources import ArraySource
from repro.serve import ModelStore

D, K, CLIENTS = 6, 3, 4

rng = np.random.default_rng(0)
mus = rng.normal(0, 5, (K, D)).astype(np.float32)

# ---- 1. out-of-core clients: heterogeneous slices of one mixture ----
clients = []
for c in range(CLIENTS):
    weights = rng.dirichlet(np.full(K, 0.5))
    y = rng.choice(K, 3000, p=weights)
    clients.append(ArraySource(
        (mus[y] + rng.normal(0, 0.7, (3000, D))).astype(np.float32)))


class PublishEachRound:
    """Delegating strategy wrapper: identical federation math, plus one
    ``store.publish`` of the new global model after every server
    combine — the trainer side of the §10 hot-swap protocol."""

    def __init__(self, strategy, store):
        self._strategy = strategy
        self._store = store
        self._round = 0

    def __getattr__(self, name):
        return getattr(self._strategy, name)

    def server_combine(self, state, total):
        state = self._strategy.server_combine(state, total)
        self._round += 1
        self._store.publish(state.gmm, {"round": self._round})
        time.sleep(0.3)   # stand-in for real client/network round latency
        return state


with tempfile.TemporaryDirectory() as root:
    store = ModelStore(root)

    # fit_federated's strategy seam takes any FederationStrategy — the
    # wrapper rides the same runtime as the named "dem" strategy
    base = DEMStrategy(k=K, covariance_type="diag", backend="auto",
                       chunk=None, init="separated", host=True,
                       tol=1e-4, reg_covar=1e-6)

    def train():
        fit_federated(clients, strategy=PublishEachRound(base, store),
                      key=jax.random.key(0))

    trainer = threading.Thread(target=train)
    trainer.start()

    # ---- 2. serve while training: hot swap as each round lands ----
    while store.latest_version() is None:   # wait for round 1
        time.sleep(0.01)
    scorer = Scorer.from_checkpoint(root, "anomaly", slots=4,
                                    rows_per_slot=256)

    id_rows = lambda: (mus[rng.choice(K, 256)]
                       + rng.normal(0, 0.7, (256, D))).astype(np.float32)
    served = []
    while trainer.is_alive() or store.latest_version() > max(
            (v for v, _ in served), default=0):
        scores = scorer.score(id_rows())
        served.append((scorer.model_version, float(np.median(scores))))
        time.sleep(0.005)
    trainer.join()

    versions = [v for v, _ in served]
    print(f"served {len(served)} batches across model versions "
          f"{sorted(set(versions))} (hot-swapped {len(set(versions)) - 1} "
          f"times, zero requests dropped)")
    print("median anomaly score by round:",
          [f"v{v}:{s:.2f}" for v, s in served[:: max(1, len(served) // 6)]])

    # ---- 3. the converged detector: ID vs OOD traffic ----
    ood = rng.normal(14.0, 1.0, (256, D)).astype(np.float32)
    id_score = float(np.median(scorer.score(id_rows())))
    ood_score = float(np.median(scorer.score(ood)))
    print(f"in-distribution anomaly score:  {id_score:.2f}   (model "
          f"v{scorer.model_version})")
    print(f"out-of-distribution score:      {ood_score:.2f}   "
          f"(higher = flagged)")
    assert ood_score > id_score
