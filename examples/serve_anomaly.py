"""Serve a small transformer with batched requests + the FedGenGMM
activation monitor (the paper's technique as a first-class serving
feature): each serving shard fits a local GMM over the hidden-state
features of its traffic; ONE communication round builds the global
monitor; incoming batches are scored online.

    PYTHONPATH=src python examples/serve_anomaly.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill_forward
from repro.monitor import FedGMMMonitor, MonitorConfig

cfg = get_config("internlm2-1.8b", "smoke")
params = init_params(jax.random.key(0), cfg)
rng = np.random.default_rng(0)

# ---- 1. batched serving: prefill + a few decode steps ----
B, S = 8, 48
prompt = jnp.asarray(rng.zipf(1.5, (B, S)).clip(0, 99), jnp.int32)
prefill = jax.jit(lambda p, b: prefill_forward(p, cfg, b, capacity=S + 16))
step = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))

t0 = time.time()
logits, cache = prefill(params, {"tokens": prompt})
tok = jnp.argmax(logits, -1).astype(jnp.int32)
generated = [tok]
for i in range(8):
    logits, cache = step(params, cache, tok, jnp.asarray(S + i, jnp.int32))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    generated.append(tok)
print(f"served {B} requests, 8 tokens each, in {time.time() - t0:.1f}s "
      f"(includes compile)")
print("sample continuation:", [int(g[0]) for g in generated])

# ---- 2. federated anomaly monitor over 4 serving shards ----
mon = FedGMMMonitor(cfg, MonitorConfig(k_local=2, k_global=4, h=50))
for shard in range(4):
    for _ in range(4):
        traffic = rng.zipf(1.5, (8, 32)).clip(0, 99)
        mon.observe(shard, params, {"tokens": jnp.asarray(traffic,
                                                          jnp.int32)})
mon.aggregate()  # <- the single communication round

id_batch = {"tokens": jnp.asarray(rng.zipf(1.5, (16, 32)).clip(0, 99),
                                  jnp.int32)}
ood_batch = {"tokens": jnp.asarray(
    rng.integers(400, cfg.vocab_size, (16, 32)), jnp.int32)}
print(f"in-distribution anomaly score: "
      f"{float(np.median(mon.score(params, id_batch))):.2f}")
print(f"out-of-distribution score:     "
      f"{float(np.median(mon.score(params, ood_batch))):.2f}  "
      f"(higher = flagged)")
