"""Train a reduced-config architecture end-to-end on the synthetic token
pipeline (a few hundred steps, CPU) and verify the loss drops.

    PYTHONPATH=src python examples/train_transformer.py [--arch yi-6b]
"""
import argparse

from repro.launch.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="internlm2-1.8b")
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

params, losses = train(args.arch, "smoke", steps=args.steps, batch_size=8,
                       seq_len=128, checkpoint_path="/tmp/repro_ckpt/model")
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")
assert losses[-1] < losses[0]
