"""The mesh-collective federated runtime: clients as data-axis shards.
FedGenGMM = ONE all-gather; DEM = one psum per round. Run with a forced
multi-device host platform:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/federated_sharded.py
"""
import os

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import FitConfig, GMMEstimator
from repro.core import partition
from repro.core.dem import fed_kmeans_centers
from repro.distributed import dem_sharded, fedgen_sharded

mesh = jax.make_mesh((len(jax.devices()),), ("data",))
print(f"mesh: {mesh}")

rng = np.random.default_rng(0)
mus = rng.normal(0, 5, (4, 6)).astype(np.float32)
y = rng.integers(0, 4, 6000)
x = (mus[y] + rng.normal(0, 0.5, (6000, 6))).astype(np.float32)
split = partition(rng, x, y, 16, "dirichlet", 0.3)
data, mask = jnp.asarray(split.data), jnp.asarray(split.mask)
xj = jnp.asarray(x)

# the sharded runtime consumes the same FitConfig as the facades
cfg = FitConfig()
res = fedgen_sharded(mesh, jax.random.key(0), data, mask, k=4, k_global=4,
                     h=80, config=cfg)
print(f"FedGenGMM (1 all-gather):   ll={float(res.global_gmm.score(xj)):.4f}")

centers = fed_kmeans_centers(jax.random.key(1), split, 4)
gmm, rounds = dem_sharded(mesh, jax.random.key(2), data, mask, 4, centers,
                          config=cfg.replace(max_iter=100))
print(f"DEM ({int(rounds)} psum rounds):       ll={float(gmm.score(xj)):.4f}")

bench = GMMEstimator(4, seed=3).fit(xj)
print(f"non-federated benchmark:    ll={float(bench.score(xj)):.4f}")
