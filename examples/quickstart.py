"""Quickstart: one-shot federated GMM learning (FedGenGMM) in ~30 lines,
through the public estimator API (`repro.api`, DESIGN.md §8).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.api import FedGenGMM, GMMEstimator
from repro.core import partition

# 1. a planted 4-component mixture, 3000 points
rng = np.random.default_rng(0)
mus = rng.normal(0, 5, (4, 8)).astype(np.float32)
y = rng.integers(0, 4, 3000)
x = (mus[y] + rng.normal(0, 0.6, (3000, 8))).astype(np.float32)

# 2. heterogeneous split over 10 clients (Dirichlet alpha = 0.2)
split = partition(rng, x, y, n_clients=10, scheme="dirichlet", alpha=0.2)
print("client sizes:", split.sizes)

# 3. the one-shot federated pipeline: local EM -> 1 round -> merge ->
#    synthetic sample -> global EM. The same runner accepts a list of
#    per-client DataSources for the out-of-core regime (out_of_core.py).
result = FedGenGMM(k_clients=4, k_global=4, h=100, seed=0).run(split)
print(f"communication rounds: {result.comm.rounds}")
print(f"uplink floats:        {result.comm.uplink_floats} "
      f"(raw data would be {x.size})")

# 4. compare against the non-federated benchmark
bench = GMMEstimator(4, seed=1).fit(x)
print(f"federated  avg log-likelihood: "
      f"{float(result.global_gmm.score(jnp.asarray(x))):.4f}")
print(f"central    avg log-likelihood: "
      f"{float(bench.score(x)):.4f}")
