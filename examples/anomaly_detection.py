"""End-to-end anomaly detection (the paper's §5.8 pipeline) on the
VEHICLE-like dataset: heterogeneous clients, one-shot aggregation, and
AUC-PR evaluation against DEM and the non-federated benchmark.

    PYTHONPATH=src python examples/anomaly_detection.py
"""
import sys
from pathlib import Path


sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks.common import load_quick, run_methods

ds = load_quick("vehicle")
print(f"dataset: {ds.name}  train={ds.x_train.shape}  "
      f"anomaly_ratio={ds.anomaly_ratio}")

# chunk_size streams training AND anomaly scoring through the engine in
# O(chunk·K) memory — the edge-client mode; drop it for full-batch.
for alpha in (1, 2):
    print(f"\n== Quantity(alpha={alpha}) heterogeneity ==")
    res = run_methods(ds, alpha, seed=0, chunk_size=1024)
    for method, r in res.items():
        print(f"  {method:8s} AUC-PR={r['auc_pr']:.3f} "
              f"loglik={r['loglik']:8.3f} rounds={r['rounds']:>3}")
