#!/usr/bin/env python
"""Repo-hygiene gate (CI `hygiene` lane; run locally with
``python tools/check_hygiene.py``).

Fails on:

- committed Python bytecode — ``__pycache__`` directories or
  ``.pyc``/``.pyo`` files in the git index. This is a regression class
  this repo has actually shipped (22 ``.pyc`` files rode along in the
  PR 1→2 window), so it is enforced rather than trusted to
  ``.gitignore``, which only guards *untracked* files: ``git add -f``,
  IDE auto-stage, or bytecode committed before the ignore rule all slip
  straight past it.
- upward imports — any module under ``repro.core`` or ``repro.fed``
  importing ``repro.api`` at module top. The facade sits ABOVE the core
  and the federation runtime (DESIGN.md §8/§9); the deprecation shims
  lazily import it at call time, and a module-level import would close
  an import cycle that only surfaces as an opaque partially-initialized-
  module error depending on which package a user imports first.

Pure stdlib (the import guard is an AST walk, no repro import) and no
test collection here — the companion ``pytest --collect-only`` gate
needs the real dependency stack and runs as its own CI step (see
.github/workflows/ci.yml).
"""
from __future__ import annotations

import ast
import subprocess
import sys
from pathlib import Path

BYTECODE_SUFFIXES = (".pyc", ".pyo")

# Packages that must never import the facade at module top (the facade
# imports THEM).
LAYERED_PACKAGES = ("src/repro/core", "src/repro/fed")
FORBIDDEN_PREFIX = "repro.api"


def tracked_files(repo_root: Path) -> list[str]:
    out = subprocess.run(["git", "ls-files"], cwd=repo_root,
                         capture_output=True, text=True, check=True)
    return out.stdout.splitlines()


def bytecode_violations(paths: list[str]) -> list[str]:
    return sorted(
        p for p in paths
        if "__pycache__" in Path(p).parts or p.endswith(BYTECODE_SUFFIXES))


def _module_level_imports(tree: ast.Module):
    """Top-of-module import nodes only: imports inside function/class
    bodies are the sanctioned lazy pattern and stay legal."""
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            yield node
        elif isinstance(node, (ast.If, ast.Try)):  # guarded module imports
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Import, ast.ImportFrom)):
                    yield sub


def import_cycle_violations(repo_root: Path) -> list[str]:
    """``repro.core`` / ``repro.fed`` modules importing ``repro.api`` at
    module top (the facade layering rule, DESIGN.md §9)."""
    bad = []
    for pkg in LAYERED_PACKAGES:
        for path in sorted((repo_root / pkg).rglob("*.py")):
            tree = ast.parse(path.read_text(), filename=str(path))
            for node in _module_level_imports(tree):
                if isinstance(node, ast.Import):
                    names = [a.name for a in node.names]
                else:
                    names = [node.module or ""]
                for name in names:
                    if name == FORBIDDEN_PREFIX or name.startswith(
                            FORBIDDEN_PREFIX + "."):
                        bad.append(
                            f"{path.relative_to(repo_root)}:{node.lineno} "
                            f"imports {name} at module top")
    return bad


def main() -> int:
    repo_root = Path(__file__).resolve().parent.parent
    bad = bytecode_violations(tracked_files(repo_root))
    if bad:
        print("committed Python bytecode (delete and add to .gitignore):")
        for p in bad:
            print(f"  {p}")
        return 1
    cycles = import_cycle_violations(repo_root)
    if cycles:
        print("layering violations (facade imports below repro.api; "
              "lazy-import it at call time instead):")
        for c in cycles:
            print(f"  {c}")
        return 1
    print(f"hygiene OK: no bytecode among {len(tracked_files(repo_root))} "
          f"tracked files; no repro.core/repro.fed module imports "
          f"repro.api at module top")
    return 0


if __name__ == "__main__":
    sys.exit(main())
