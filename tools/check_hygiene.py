#!/usr/bin/env python
"""Repo-hygiene gate (CI `hygiene` lane; run locally with
``python tools/check_hygiene.py``).

Fails on:

- committed Python bytecode — ``__pycache__`` directories or
  ``.pyc``/``.pyo`` files in the git index. This is a regression class
  this repo has actually shipped (22 ``.pyc`` files rode along in the
  PR 1→2 window), so it is enforced rather than trusted to
  ``.gitignore``, which only guards *untracked* files: ``git add -f``,
  IDE auto-stage, or bytecode committed before the ignore rule all slip
  straight past it.
- upward imports — any module under ``repro.core``, ``repro.fed`` or
  ``repro.serve`` importing ``repro.api`` at module top. The facade sits
  ABOVE the core, the federation runtime and the serving engine
  (DESIGN.md §8/§9/§10); the deprecation shims lazily import it at call
  time, and a module-level import would close an import cycle that only
  surfaces as an opaque partially-initialized-module error depending on
  which package a user imports first.
- missing public docstrings — every public def/class (and public method)
  in the facade (``repro.api``) and the serving package (``repro.serve``)
  must carry a docstring, including the defs the facade RE-EXPORTS in its
  ``__all__`` from lower layers (e.g. ``FitConfig`` lives in
  ``repro.core.config`` but is public surface). These two packages ARE
  the documentation users hit first; an undocumented name there is a doc
  regression, caught here rather than in review.

Pure stdlib (the import and docstring guards are AST walks, no repro
import) and no test collection here — the companion
``pytest --collect-only`` gate needs the real dependency stack and runs
as its own CI step (see .github/workflows/ci.yml).
"""
from __future__ import annotations

import ast
import subprocess
import sys
from pathlib import Path

BYTECODE_SUFFIXES = (".pyc", ".pyo")

# Packages that must never import the facade at module top (the facade
# imports THEM).
LAYERED_PACKAGES = ("src/repro/core", "src/repro/fed", "src/repro/serve")
FORBIDDEN_PREFIX = "repro.api"

# Packages whose public names must all carry docstrings (the user-facing
# doc surface), and the source root for resolving their re-exports.
DOC_PACKAGES = ("src/repro/api", "src/repro/serve")
# Single modules below the facade that are nonetheless user-facing doc
# surface (their classes are constructed directly by users): the uplink
# transforms ride `fit_federated(transform=...)` and the async runtime's
# AsyncPolicy/ClientExecutor/run_async ride `fit_federated(async_policy=
# ...)` / estimator facades — every public name there must be documented.
DOC_MODULES = ("src/repro/fed/transforms.py",
               "src/repro/fed/async_runtime.py")
SRC_ROOT = "src"


def tracked_files(repo_root: Path) -> list[str]:
    out = subprocess.run(["git", "ls-files"], cwd=repo_root,
                         capture_output=True, text=True, check=True)
    return out.stdout.splitlines()


def bytecode_violations(paths: list[str]) -> list[str]:
    return sorted(
        p for p in paths
        if "__pycache__" in Path(p).parts or p.endswith(BYTECODE_SUFFIXES))


def _module_level_imports(tree: ast.Module):
    """Top-of-module import nodes only: imports inside function/class
    bodies are the sanctioned lazy pattern and stay legal."""
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            yield node
        elif isinstance(node, (ast.If, ast.Try)):  # guarded module imports
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Import, ast.ImportFrom)):
                    yield sub


def import_cycle_violations(repo_root: Path) -> list[str]:
    """``repro.core`` / ``repro.fed`` modules importing ``repro.api`` at
    module top (the facade layering rule, DESIGN.md §9)."""
    bad = []
    for pkg in LAYERED_PACKAGES:
        for path in sorted((repo_root / pkg).rglob("*.py")):
            tree = ast.parse(path.read_text(), filename=str(path))
            for node in _module_level_imports(tree):
                if isinstance(node, ast.Import):
                    names = [a.name for a in node.names]
                else:
                    names = [node.module or ""]
                for name in names:
                    if name == FORBIDDEN_PREFIX or name.startswith(
                            FORBIDDEN_PREFIX + "."):
                        bad.append(
                            f"{path.relative_to(repo_root)}:{node.lineno} "
                            f"imports {name} at module top")
    return bad


def _undocumented_defs(tree: ast.Module, rel: str) -> list[str]:
    """Public top-level defs/classes and public methods without a
    docstring. Leading-underscore names (dunders included) are internal
    by convention; assignments (constants) cannot carry docstrings and
    are skipped."""
    bad = []

    def check(node, prefix=""):
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef)):
                continue
            name = prefix + child.name
            if not child.name.startswith("_") and \
                    ast.get_docstring(child) is None:
                bad.append(f"{rel}:{child.lineno} {name}")
            if isinstance(child, ast.ClassDef):
                check(child, name + ".")

    check(tree)
    return bad


def _exported_names(init_tree: ast.Module) -> tuple[list[str], dict]:
    """(__all__ entries, imported-name -> source module) of a package
    ``__init__``."""
    exported, origins = [], {}
    for node in init_tree.body:
        if isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets
                       if isinstance(t, ast.Name)]
            if "__all__" in targets and isinstance(node.value,
                                                   (ast.List, ast.Tuple)):
                exported = [elt.value for elt in node.value.elts
                            if isinstance(elt, ast.Constant)]
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                origins[alias.asname or alias.name] = (node.module,
                                                       alias.name)
    return exported, origins


def docstring_violations(repo_root: Path) -> list[str]:
    """Public names in the doc-surface packages without docstrings —
    both the defs that live there and the lower-layer defs their
    ``__init__.__all__`` re-exports."""
    bad = []
    seen_files = set()
    for mod in DOC_MODULES:
        path = repo_root / mod
        seen_files.add(path)
        tree = ast.parse(path.read_text(), filename=str(path))
        bad.extend(_undocumented_defs(tree,
                                      str(path.relative_to(repo_root))))
    for pkg in DOC_PACKAGES:
        for path in sorted((repo_root / pkg).rglob("*.py")):
            seen_files.add(path)
            tree = ast.parse(path.read_text(), filename=str(path))
            bad.extend(_undocumented_defs(tree,
                                          str(path.relative_to(repo_root))))
        init = repo_root / pkg / "__init__.py"
        if not init.exists():
            continue
        exported, origins = _exported_names(ast.parse(init.read_text()))
        for name in exported:
            if name not in origins:
                continue
            module, src_name = origins[name]
            mod_path = repo_root / SRC_ROOT / Path(*module.split("."))
            mod_path = (mod_path / "__init__.py"
                        if mod_path.is_dir()
                        else mod_path.with_suffix(".py"))
            if not mod_path.exists() or mod_path in seen_files:
                continue  # in-package origin already scanned above
            mod_tree = ast.parse(mod_path.read_text())
            for node in mod_tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)) \
                        and node.name == src_name \
                        and ast.get_docstring(node) is None:
                    bad.append(
                        f"{mod_path.relative_to(repo_root)}:{node.lineno} "
                        f"{src_name} (re-exported by {pkg}/__init__.py)")
    return sorted(set(bad))


def main() -> int:
    repo_root = Path(__file__).resolve().parent.parent
    bad = bytecode_violations(tracked_files(repo_root))
    if bad:
        print("committed Python bytecode (delete and add to .gitignore):")
        for p in bad:
            print(f"  {p}")
        return 1
    cycles = import_cycle_violations(repo_root)
    if cycles:
        print("layering violations (facade imports below repro.api; "
              "lazy-import it at call time instead):")
        for c in cycles:
            print(f"  {c}")
        return 1
    undocumented = docstring_violations(repo_root)
    if undocumented:
        print("public names without docstrings (repro.api / repro.serve "
              "are the user-facing doc surface):")
        for u in undocumented:
            print(f"  {u}")
        return 1
    print(f"hygiene OK: no bytecode among {len(tracked_files(repo_root))} "
          f"tracked files; no repro.core/fed/serve module imports "
          f"repro.api at module top; every public repro.api/repro.serve "
          f"name is documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
