#!/usr/bin/env python
"""Repo-hygiene gate (CI `hygiene` lane; run locally with
``python tools/check_hygiene.py``).

Fails on committed Python bytecode — ``__pycache__`` directories or
``.pyc``/``.pyo`` files in the git index. This is a regression class this
repo has actually shipped (22 ``.pyc`` files rode along in the PR 1→2
window), so it is enforced rather than trusted to ``.gitignore``, which
only guards *untracked* files: ``git add -f``, IDE auto-stage, or bytecode
committed before the ignore rule all slip straight past it.

Pure stdlib and no test collection here — the companion
``pytest --collect-only`` gate needs the real dependency stack and runs as
its own CI step (see .github/workflows/ci.yml).
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

BYTECODE_SUFFIXES = (".pyc", ".pyo")


def tracked_files(repo_root: Path) -> list[str]:
    out = subprocess.run(["git", "ls-files"], cwd=repo_root,
                         capture_output=True, text=True, check=True)
    return out.stdout.splitlines()


def bytecode_violations(paths: list[str]) -> list[str]:
    return sorted(
        p for p in paths
        if "__pycache__" in Path(p).parts or p.endswith(BYTECODE_SUFFIXES))


def main() -> int:
    repo_root = Path(__file__).resolve().parent.parent
    bad = bytecode_violations(tracked_files(repo_root))
    if bad:
        print("committed Python bytecode (delete and add to .gitignore):")
        for p in bad:
            print(f"  {p}")
        return 1
    print(f"hygiene OK: no bytecode among {len(tracked_files(repo_root))} "
          f"tracked files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
